"""BENCH_cluster: the measured perf trajectory of the global repack planner
(ISSUE 6 satellite — ROADMAP's first `BENCH_*.json`).

Replays a Llama3-calibrated failure trace (tiny native geometry: n1=4,
pp=2, 4 replicas + 1 spare domain, rate cranked so the 32-GPU job sees
events) through `repro.cluster.GreedyAllocator` with REAL packed trees:
every accepted plan is executed by the reshard engine
(`transition_staged_trees`) and the cost model's predicted bytes are
checked against the executed `TransferStats` ledger — the two must match
exactly, every transition. Records allocator plan latency (host wall
time) and the predicted-vs-ledger byte totals.

``python -m benchmarks.bench_cluster`` appends a run record to
``BENCH_cluster.json`` at the repo root; the `run()` entry point feeds
`benchmarks/run.py` CSV rows from the same replay.
"""
import json
import os
import time

import jax
import numpy as np

from repro.cluster import (
    AllocatorConfig, GoodputModel, GreedyAllocator, TransitionCostModel,
)
from repro.core import ntp_train as nt
from repro.core.failure_model import FailureTraceConfig, simulate_events
from repro.reshard.transition import transition_staged_trees
from repro.runtime.events import ClusterHealth, DeadReplicaError, StagedHealth

N1 = 4           # scale-up domain size of the replayed job
# the 100k-GPU trace row (§2.11 scale gate): generate + scan a 2-week
# mixed-taxonomy trace at paper scale. Keys guarded by the bench-smoke
# schema test (tests/test_bench_cluster_smoke.py).
TRACE_100K_KEYS = (
    "n_gpus", "days", "mix", "events", "events_per_kind", "generate_s",
    "events_per_s", "scan_samples", "scan_s",
)
PP = 2
N_REP = 4        # active replicas (stage domains) — 32 GPUs total
SPARES = 1
SAMPLE_EVERY_H = 12.0
DAYS = 30.0
RATE_MULT = 128.0   # Llama3 rates are per-32k-GPU: crank so 32 GPUs see events
BENCH_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_cluster.json")


def _model_cfg():
    return nt.NTPModelConfig(d_model=64, n_kv_groups=4, q_per_kv=2,
                             head_dim=16, d_ff=256, unit_rows=64,
                             n_layers=4, vocab=128)


def replay():
    """One trace replay. Returns the measurement dict."""
    cfg = _model_cfg()
    tcfg = FailureTraceConfig(
        n_gpus=N1 * PP * N_REP, domain_size=N1, days=DAYS,
        rate_multiplier=RATE_MULT, seed=0,
    )
    ev = simulate_events(tcfg)
    times = np.arange(0.0, DAYS * 24.0, SAMPLE_EVERY_H)

    gm = GoodputModel(n1=N1)
    cost = None      # bound from live trees below
    alloc = GreedyAllocator(AllocatorConfig(horizon_steps=200), goodput=gm)

    trees = None
    cur = None
    lat_ms, local_gp, global_gp = [], [], []
    predicted_total = executed_total = 0
    transitions = mismatches = skipped = 0
    for t in times:
        counts = ev.failed_counts_at(t, tcfg.n_domains, N1)
        # global domain g -> (stage g % pp, domain g // pp); the active job
        # owns the first N_REP*PP domains, the spare pool the rest
        stage_counts = [
            np.asarray([counts[r * PP + s] for r in range(N_REP)], dtype=int)
            for s in range(PP)
        ]
        pool = int(sum(
            counts[N_REP * PP + i] == 0
            for i in range(min(SPARES, tcfg.n_domains - N_REP * PP))
        ))
        health = StagedHealth(tuple(
            ClusterHealth(N1, tuple(int(x) for x in c)) for c in stage_counts
        ))
        t0 = time.perf_counter()
        try:
            gp = alloc.plan(health, spares=pool, current=cur)
        except DeadReplicaError:
            skipped += 1
            cur, trees = None, None     # job lost: restart from checkpoint
            continue
        lat_ms.append((time.perf_counter() - t0) * 1e3)
        local_gp.append(gm.goodput(stage_counts))
        global_gp.append(gp.goodput)

        if trees is None:
            # (re)materialize packed trees at the fresh plan — free packing
            params = nt.pack_params(
                cfg, nt.init_canonical(cfg, jax.random.PRNGKey(0)),
                gp.staged_plan)
            trees = [params, jax.tree.map(np.zeros_like, params)]
            alloc.bind(cost=TransitionCostModel.from_trees(cfg, trees, pp=PP))
            cost = alloc.cost
        elif gp.staged_plan != cur:
            trees, stats = transition_staged_trees(
                cfg, trees, cur, gp.staged_plan, copy_unchanged=False)
            transitions += 1
            predicted_total += gp.predicted_bytes
            executed_total += stats.bytes_moved
            if gp.predicted_bytes != stats.bytes_moved:
                mismatches += 1
        cur = gp.staged_plan

    lat = np.asarray(lat_ms)
    return {
        "config": {
            "n1": N1, "pp": PP, "replicas": N_REP, "spares": SPARES,
            "days": DAYS, "rate_multiplier": RATE_MULT,
            "sample_every_h": SAMPLE_EVERY_H, "seed": tcfg.seed,
            "model": "d64-L4-kv4",
        },
        "samples": int(len(lat)),
        "dead_skipped": int(skipped),
        "transitions": int(transitions),
        "plan_latency_ms": {
            "mean": round(float(lat.mean()), 3),
            "p95": round(float(np.percentile(lat, 95)), 3),
            "max": round(float(lat.max()), 3),
        },
        "predicted_bytes": int(predicted_total),
        "executed_bytes": int(executed_total),
        "predicted_matches_ledger": mismatches == 0,
        "goodput": {
            "stage_local": round(float(np.mean(local_gp)), 5),
            "global": round(float(np.mean(global_gp)), 5),
        },
        "trace_100k": trace_100k(),
    }


def trace_100k(n_gpus: int = 100_352, days: float = 14.0):
    """§2.11's scale gate, measured: generate a 100k-GPU, 2-week trace with
    every taxonomy kind mixed in, then scan failed counts at hourly
    resolution with the vectorized arrival-sorted path. The acceptance bar
    is generate + scan < 10 s; record keys are ``TRACE_100K_KEYS``."""
    from repro.core.failure_model import KIND_NAMES

    # §2.3's 3× failure spike, with degradations well above the failure
    # rate (ByteDance taxonomy: stragglers/flapping links dominate hard
    # failures) — a dense ~100k-event stress trace, not a quiet one
    mix = {"straggler_rate_mult": 20.0, "link_rate_mult": 10.0,
           "sdc_rate_mult": 5.0}
    tcfg = FailureTraceConfig(
        n_gpus=n_gpus, domain_size=64, days=days, rate_multiplier=3.0,
        seed=0, **mix,
    )
    t0 = time.perf_counter()
    ev = simulate_events(tcfg)
    gen_s = time.perf_counter() - t0
    times = np.arange(0.0, days * 24.0, 1.0)
    t0 = time.perf_counter()
    counts = ev.failed_counts_scan(times, tcfg.n_domains, tcfg.domain_size)
    scan_s = time.perf_counter() - t0
    assert counts.shape == (len(times), tcfg.n_domains)
    per_kind = {
        name: int(ev.kind_mask(code).sum())
        for code, name in enumerate(KIND_NAMES)
    }
    return {
        "n_gpus": n_gpus,
        "days": days,
        "mix": mix,
        "events": int(ev.n_events),
        "events_per_kind": per_kind,
        "generate_s": round(gen_s, 4),
        "events_per_s": int(ev.n_events / gen_s) if gen_s > 0 else 0,
        "scan_samples": int(len(times)),
        "scan_s": round(scan_s, 4),
    }


def run():
    """benchmarks/run.py entry point — CSV rows from one replay."""
    m = replay()
    lat, gp = m["plan_latency_ms"], m["goodput"]
    rows = [
        {"name": "cluster/plan_latency_ms/mean", "value": lat["mean"],
         "derived": f"p95={lat['p95']} max={lat['max']} over "
                    f"{m['samples']} samples"},
        {"name": "cluster/transitions", "value": m["transitions"],
         "derived": f"{m['dead_skipped']} dead-skipped samples"},
        {"name": "cluster/predicted_bytes", "value": m["predicted_bytes"],
         "derived": f"executed={m['executed_bytes']} "
                    f"match={m['predicted_matches_ledger']}"},
        {"name": "cluster/goodput/global_vs_stage_local",
         "value": round(gp["global"] - gp["stage_local"], 5),
         "derived": f"global={gp['global']} stage_local={gp['stage_local']}"},
    ]
    tk = m["trace_100k"]
    rows.append(
        {"name": "cluster/trace_100k/generate_plus_scan_s",
         "value": round(tk["generate_s"] + tk["scan_s"], 3),
         "derived": f"{tk['events']} events at {tk['events_per_s']}/s, "
                    f"scan {tk['scan_samples']} samples in "
                    f"{tk['scan_s']} s"})
    return rows


def main():
    m = replay()
    path = os.path.abspath(BENCH_PATH)
    doc = {"bench": "cluster", "schema": 1, "runs": []}
    if os.path.exists(path):
        with open(path) as f:
            doc = json.load(f)
    m["date"] = time.strftime("%Y-%m-%d")
    doc["runs"].append(m)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"appended run {len(doc['runs'])} to {path}")
    print(json.dumps(m, indent=2))


if __name__ == "__main__":
    main()
