"""Fig. 2: effect of scale-up-domain size / TP cap on per-GPU throughput
when scaling the 480B workload (analytic perf model)."""
from repro.core.perf_model import Hardware, Workload, best_config


def run():
    wl = Workload()  # 480B, 16M tokens/minibatch
    rows = []
    base = None
    for n_gpus in (8_192, 16_384, 32_768):
        for tp_limit in (8, 16, 32):
            hw = Hardware(domain_size=tp_limit)
            r = best_config(hw, wl, n_gpus, tp_limit=tp_limit)
            if r is None:
                continue
            if base is None:
                base = r["per_gpu_tput"]
            rows.append({
                "name": f"fig2/gpus{n_gpus}/nvl{tp_limit}",
                "value": round(r["per_gpu_tput"] / base, 3),
                "derived": f"tp={r['tp']} pp={r['pp']} dp={r['dp']} "
                           f"bubble={r['pp_bubble']/r['total']:.2f} "
                           "(paper: NVL8 vs NVL32 gap grows with scale)",
            })
    return rows
