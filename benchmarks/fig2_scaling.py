"""Fig. 2: effect of scale-up-domain size / TP cap on per-GPU throughput
when scaling the 480B workload (analytic perf model), plus the
measured-vs-analytic cross-check of the live runtime's slowest-stage
slowdown rule against `perf_model.staged_iteration_time` (DESIGN.md §2.6)."""
from repro.core.perf_model import (
    Hardware, Parallel, Workload, best_config, iteration_time,
    staged_iteration_time,
)
from repro.core.policies import WorkloadGeometry, staged_rel_iter_times


def run():
    wl = Workload()  # 480B, 16M tokens/minibatch
    rows = []
    base = None
    for n_gpus in (8_192, 16_384, 32_768):
        for tp_limit in (8, 16, 32):
            hw = Hardware(domain_size=tp_limit)
            r = best_config(hw, wl, n_gpus, tp_limit=tp_limit)
            if r is None:
                continue
            if base is None:
                base = r["per_gpu_tput"]
            rows.append({
                "name": f"fig2/gpus{n_gpus}/nvl{tp_limit}",
                "value": round(r["per_gpu_tput"] / base, 3),
                "derived": f"tp={r['tp']} pp={r['pp']} dp={r['dp']} "
                           f"bubble={r['pp_bubble']/r['total']:.2f} "
                           "(paper: NVL8 vs NVL32 gap grows with scale)",
            })

    # ---- staged cross-check: runtime slowdown rule vs analytic perf model.
    # A TP32×PP8 replica with ONE stage at reduced TP: the runtime predicts
    # rel iter time from `staged_rel_iter_times` (head-quantized slowdown,
    # full batch kept — the step-metrics number); the perf model predicts it
    # as staged_iteration_time/healthy (flops+comm terms). Both implement
    # the slowest-stage gating, so they must agree to model error (<~10%).
    xcheck_gpus = 32_768
    hw = Hardware(domain_size=32)
    par = Parallel(tp=32, pp=8, dp=xcheck_gpus // (32 * 8))
    geom = WorkloadGeometry(n_heads=128, local_batch=8)
    healthy = iteration_time(hw, wl, par)["total"]
    for tp_red in (30, 28):
        stage_tps = (tp_red,) + (32,) * (par.pp - 1)
        stage_rels = staged_rel_iter_times(
            [list(stage_tps)], 32, geom,
            local_batches=[geom.local_batch], local_batch=geom.local_batch,
        )
        runtime_rel = max(stage_rels)
        analytic_rel = staged_iteration_time(hw, wl, par, stage_tps)["total"] / healthy
        rows.append({
            "name": f"fig2/xcheck/tp{tp_red}of32_pp8/runtime_rel",
            "value": round(runtime_rel, 4),
            "derived": f"per-stage rels {[round(r, 3) for r in stage_rels]} "
                       "(slowest stage gates)",
        })
        rows.append({
            "name": f"fig2/xcheck/tp{tp_red}of32_pp8/analytic_rel",
            "value": round(analytic_rel, 4),
            "derived": f"staged_iteration_time(min={tp_red})/healthy; "
                       f"gap vs runtime {abs(analytic_rel - runtime_rel):.4f}",
        })
    return rows
