"""Fig. 4: Llama3-calibrated failure traces — failed fraction over time."""
import numpy as np

from repro.core.failure_model import (
    FailureTraceConfig, fraction_time_above, simulate_trace,
    steady_state_failed_fraction,
)


def run():
    rows = []
    for mult in (1.0, 3.0):
        cfg = FailureTraceConfig(rate_multiplier=mult, seed=3)
        t, failed = simulate_trace(cfg)
        frac = failed / cfg.n_gpus
        rows.append({
            "name": f"fig4/rate{mult:g}x/mean_failed_frac",
            "value": round(float(frac.mean()), 5),
            "derived": f"steady_state={steady_state_failed_fraction(cfg):.5f}",
        })
        rows.append({
            "name": f"fig4/rate{mult:g}x/peak_failed_frac",
            "value": round(float(frac.max()), 5),
            "derived": "paper(3x): ~2x higher peak",
        })
        rows.append({
            "name": f"fig4/rate{mult:g}x/time_above_0.1%",
            "value": round(fraction_time_above(cfg, 1e-3), 3),
            "derived": "paper(1x): 0.81 (cold-start trace)",
        })
    return rows
