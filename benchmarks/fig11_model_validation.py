"""Fig. 11 analogue: validate the analytic perf model against the
XLA-compiled dry-run artifacts (we have no physical fleet; the dry-run's
HLO-derived roofline terms play the role of the measured system).

Correlates perf_model's predicted iteration time with
(compute + memory-excess + collective) time from results/dryrun for every
train_4k record."""
import numpy as np

from benchmarks.roofline import load_records
from repro.configs import get_arch
from repro.core.perf_model import Hardware, Parallel, Workload, iteration_time


def run():
    recs = [
        r for r in load_records()
        if r.get("ok") and r["shape"] == "train_4k"
    ]
    preds, meas = [], []
    rows = []
    for r in recs:
        cfg = get_arch(r["arch"])
        wl = Workload(
            n_params=float(cfg.n_active_params()),
            n_layers=cfg.n_layers,
            d_model=cfg.d_model,
            seq_len=4096,
            minibatch_tokens=256 * 4096,
        )
        hw = Hardware(domain_size=16, scaleup_bw=4 * 50e9, scaleout_bw=50e9)
        pred = iteration_time(hw, wl, Parallel(tp=16, pp=1, dp=16))["total"]
        rl = r["roofline"]
        measured = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
        preds.append(pred)
        meas.append(measured)
        rows.append({
            "name": f"fig11/{r['arch']}",
            "value": round(pred, 3),
            "derived": f"dryrun_dominant_term={measured:.3f}s",
        })
    if len(preds) >= 3:
        corr = float(np.corrcoef(np.log(preds), np.log(meas))[0, 1])
        rows.append({
            "name": "fig11/log_correlation",
            "value": round(corr, 3),
            "derived": "paper: 'highly correlated' (visual); ours across archs",
        })
    return rows
