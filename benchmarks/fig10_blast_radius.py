"""Fig. 10: sensitivity to failure blast radius (GPUs lost per failure)."""
from repro.core.availability import ClusterSpec
from repro.core.policies import throughput_loss_curve


def run():
    spec = ClusterSpec(n_gpus=32_768, domain_size=32)
    rows = []
    for br in (1, 2, 4, 8):
        curve = throughput_loss_curve(
            spec, [2e-3], samples=10, blast_radius=br, seed=br,
        )
        for m in ("dpdrop", "ntp", "ntp_pw"):
            rows.append({
                "name": f"fig10/blast{br}/{m}",
                "value": round(curve[m][0], 4),
                "derived": "paper: NTP degrades with radius but beats DP-DROP",
            })
    return rows
