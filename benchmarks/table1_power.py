"""Table 1 + §6.4 power sensitivity."""
from repro.core.policies import table1_settings
from repro.core.power import PowerModel


def run():
    rows = []
    for r in table1_settings():
        rows.append({
            "name": f"table1/{r['config']}",
            "value": r["rel_iter_time"],
            "derived": f"local_bs={r['local_bs']} power={r['power']}x "
                       "(paper: TP30 bs7 1.002, TP30-PW 1.15x .978, "
                       "TP28 bs6 1.003, TP28-PW 1.3x .999)",
        })
    pm = PowerModel()
    for p in (1.1, 1.2, 1.3):
        rows.append({
            "name": f"table1/perf_per_watt@{p}x",
            "value": round(pm.perf_per_watt_penalty(p), 4),
            "derived": "paper §6.4: -2.8% @1.1x, -6.5% @1.2x",
        })
    return rows
