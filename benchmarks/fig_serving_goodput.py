"""Serving goodput under failures: trace-mean decode goodput and SLO
attainment per fault-tolerance policy — drop-replica vs NTP vs NTP+power
boost — on the Llama3-calibrated failure/recovery trace (the serving twin
of fig4_end_to_end), plus goodput vs the REPLICA blast radius
(domains_per_replica): one GPU failure forfeits a whole
dpr × domain_size-GPU replica under drop, while NTP localizes it."""
from repro.core.availability import ClusterSpec
from repro.core.failure_model import FailureTraceConfig
from repro.serve import blast_radius_goodput, serving_goodput_trace


def run():
    spec = ClusterSpec(n_gpus=32_768, domain_size=32, domains_per_replica=8)
    rows = []
    for mult in (1.0, 3.0):
        cfg = FailureTraceConfig(
            n_gpus=spec.n_gpus, domain_size=spec.domain_size,
            days=15.0, rate_multiplier=mult, seed=3,
        )
        res = serving_goodput_trace(spec, cfg)
        for method, d in res.items():
            rows.append({
                "name": f"serve/rate{mult:g}x/{method}/goodput",
                "value": round(d["goodput"], 5),
                "derived": f"trace-mean lost={1 - d['goodput']:.4f}",
            })
            rows.append({
                "name": f"serve/rate{mult:g}x/{method}/slo_attainment",
                "value": round(d["slo_attainment"], 5),
                "derived": "capacity-weighted, 1.1x per-token latency budget",
            })
        rows.append({
            "name": f"serve/rate{mult:g}x/ntp_pw/recovered_frac",
            "value": round(res["ntp_pw"]["goodput"], 5),
            "derived": "fraction of healthy-cluster goodput NTP+boost keeps "
                       "(paper-level target: >= 0.95)",
        })

    cfg1 = FailureTraceConfig(
        n_gpus=spec.n_gpus, domain_size=spec.domain_size, days=15.0, seed=3,
    )
    br = blast_radius_goodput(spec, cfg1, radii=(1, 2, 4, 8))
    for dpr, d in br.items():
        for method, g in d.items():
            rows.append({
                "name": f"serve/blast_dpr{dpr}/{method}/goodput",
                "value": round(g, 5),
                "derived": f"replica blast radius {dpr * spec.domain_size} "
                           "GPUs per failure",
            })
    return rows
