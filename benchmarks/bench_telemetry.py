"""BENCH_telemetry: the observability spine's own cost (ISSUE 8 satellite —
extends the BENCH_*.json series).

Two sections:

* **primitives** — ns per recorder operation (span enter/exit, counter,
  gauge) against a MemorySink, plus the NULL-recorder (telemetry off) cost
  of the same call sites — the number every instrumented hot path pays;
* **overhead** — a real `NTPSession.step` loop on fake devices, recorder
  off vs on. The GATE is the additive estimate (per-step event cost from
  the primitive timings ÷ measured step time): it must stay under
  ``OVERHEAD_PCT_MAX`` of the smoke step. The measured on-vs-off medians
  are recorded next to it as evidence, but the estimate is what's gated —
  differencing two ~100 ms step medians on a shared CPU host cannot
  resolve a sub-1% effect, the additive estimate can.

Usage:
  python -m benchmarks.bench_telemetry            # measure + append
  python -m benchmarks.bench_telemetry --smoke    # quick run + schema check
  (also a `run()` module for benchmarks/run.py CSV rows)
"""
import argparse
import json
import os
import subprocess
import sys
import time

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
PATH = os.path.join(REPO, "BENCH_telemetry.json")

# recorder-on step overhead budget: the per-step telemetry work (1 span +
# 2 gauges in the orchestrated loop) must cost < 1% of a smoke step
OVERHEAD_PCT_MAX = 1.0

# schema keys the CI telemetry job pins (drift = hard failure)
TELEMETRY_KEYS = {"config", "primitives", "overhead"}


def _worker(smoke: bool) -> dict:
    import numpy as np

    import jax
    import jax.numpy as jnp

    from repro import telemetry
    from repro.optim import sgd
    from repro.runtime import NTPModelConfig, NTPSession
    from repro.telemetry import MemorySink, NULL, Recorder

    # --- primitives: ns per recorder op ------------------------------------
    n = 20_000 if smoke else 100_000

    def ns_per(f, reps=n):
        t0 = time.perf_counter()
        for _ in range(reps):
            f()
        return round((time.perf_counter() - t0) / reps * 1e9, 1)

    rec = Recorder(sinks=[MemorySink(maxlen=4096)])

    def one_span():
        with rec.span("bench.prim", k="v"):
            pass

    def null_span():
        with NULL.span("bench.prim", k="v"):
            pass

    primitives = {
        "span_ns": ns_per(one_span),
        "counter_ns": ns_per(lambda: rec.counter("bench.c", k="v")),
        "gauge_ns": ns_per(lambda: rec.gauge("bench.g", 1.0, k="v")),
        "hist_ns": ns_per(lambda: rec.hist("bench.h", 1.0, k="v")),
        "null_span_ns": ns_per(null_span),
        "null_gauge_ns": ns_per(lambda: NULL.gauge("bench.g", 1.0, k="v")),
        "ops_timed": n,
    }

    # --- overhead: a real session step, recorder off vs on -----------------
    D, N1 = 2, 4
    LB, SEQ = (4, 16) if smoke else (8, 32)
    steps = 6 if smoke else 10
    cfg = NTPModelConfig(d_model=64, n_kv_groups=4, q_per_kv=2, head_dim=16,
                         d_ff=256, unit_rows=64, n_layers=2, vocab=128)
    sess = NTPSession.create(
        cfg, jax.make_mesh((D, N1), ("data", "model")), local_batch=LB,
        optimizer=sgd(0.05), key=jax.random.PRNGKey(0),
    )
    rng = np.random.default_rng(0)

    def batch():
        return jnp.asarray(rng.integers(0, cfg.vocab, (D * LB, SEQ + 1)))

    def step_ms(recorder, n_steps):
        with telemetry.recording(recorder):
            for _ in range(2):
                m = sess.step(batch())
                jax.block_until_ready((sess.params, m["loss"]))
            ts = []
            for _ in range(n_steps):
                b = batch()
                t0 = time.perf_counter()
                m = sess.step(b)
                jax.block_until_ready((sess.params, m["loss"]))
                ts.append((time.perf_counter() - t0) * 1e3)
        return float(np.median(ts))

    off_ms = step_ms(None, steps)
    on_rec = Recorder(sinks=[MemorySink()])
    on_ms = step_ms(on_rec, steps)

    # the gated number: what the orchestrated loop's per-step telemetry
    # (1 session.step span + 2 goodput gauges) costs, from the primitive
    # timings, as a fraction of the MEASURED step
    per_step_ns = primitives["span_ns"] + 2 * primitives["gauge_ns"]
    overhead_pct = per_step_ns / (off_ms * 1e6) * 100.0

    return {
        "config": {"model": "d64-L2-kv4", "data": D, "n1": N1,
                   "local_batch": LB, "seq_len": SEQ, "steps_timed": steps,
                   "smoke": smoke, "backend": jax.default_backend()},
        "primitives": primitives,
        "overhead": {
            "step_ms_off": round(off_ms, 2),
            "step_ms_on": round(on_ms, 2),
            "per_step_telemetry_ns": round(per_step_ns, 1),
            "overhead_pct_estimate": round(overhead_pct, 5),
            "budget_pct": OVERHEAD_PCT_MAX,
            "within_budget": bool(overhead_pct < OVERHEAD_PCT_MAX),
            "events_recorded": len(on_rec.sinks[0]),
        },
    }


def measure(smoke: bool = False) -> dict:
    """Spawn the measurement subprocess (needs its own XLA device count)."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS=os.environ.get("JAX_PLATFORMS", ""),
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(REPO, "src"), REPO,
                    os.environ.get("PYTHONPATH", "")]))
    cmd = [sys.executable, "-m", "benchmarks.bench_telemetry", "--worker"]
    if smoke:
        cmd.append("--smoke")
    out = subprocess.run(cmd, capture_output=True, text=True, env=env,
                         cwd=REPO, timeout=1200)
    for line in reversed(out.stdout.splitlines()):
        if line.startswith("TELEMETRY_JSON "):
            return json.loads(line[len("TELEMETRY_JSON "):])
    raise RuntimeError(
        f"telemetry bench worker produced no report (rc={out.returncode}):\n"
        f"{out.stdout[-2000:]}\n{out.stderr[-2000:]}")


def _check_schema(path: str) -> list:
    """CI drift guard: the committed BENCH file's latest run must carry
    exactly the top-level keys this code produces."""
    errs = []
    if not os.path.exists(path):
        return [f"{os.path.basename(path)} missing"]
    with open(path) as f:
        doc = json.load(f)
    if doc.get("bench") != "telemetry" or not doc.get("runs"):
        errs.append(f"{os.path.basename(path)}: bad header/empty runs")
        return errs
    got = set(doc["runs"][-1]) - {"date"}
    if got != TELEMETRY_KEYS:
        errs.append(f"{os.path.basename(path)}: run keys {sorted(got)} != "
                    f"expected {sorted(TELEMETRY_KEYS)}")
    return errs


def run():
    """benchmarks/run.py entry point — CSV rows from one full measurement."""
    m = measure(smoke=False)
    p, o = m["primitives"], m["overhead"]
    return [
        {"name": "telemetry/span_ns", "value": p["span_ns"],
         "derived": f"counter={p['counter_ns']} gauge={p['gauge_ns']} "
                    f"null_span={p['null_span_ns']}"},
        {"name": "telemetry/step_overhead_pct",
         "value": o["overhead_pct_estimate"],
         "derived": f"budget={o['budget_pct']} ok={o['within_budget']} "
                    f"off_ms={o['step_ms_off']} on_ms={o['step_ms_on']}"},
    ]


def _append(rec: dict) -> None:
    doc = {"bench": "telemetry", "schema": 1, "runs": []}
    if os.path.exists(PATH):
        with open(PATH) as f:
            doc = json.load(f)
    rec["date"] = time.strftime("%Y-%m-%d")
    doc["runs"].append(rec)
    with open(PATH, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"appended run {len(doc['runs'])} to {PATH}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small geometry + committed-BENCH schema check "
                         "(the CI telemetry job's contract); does not write")
    ap.add_argument("--worker", action="store_true", help=argparse.SUPPRESS)
    args = ap.parse_args()

    if args.worker:
        doc = _worker(args.smoke)
        print("TELEMETRY_JSON " + json.dumps(doc))
        return

    m = measure(smoke=args.smoke)
    print(json.dumps(m, indent=2))
    if not m["overhead"]["within_budget"]:
        sys.exit("recorder-on step overhead above budget "
                 f"({m['overhead']})")
    if args.smoke:
        errs = _check_schema(PATH)
        if errs:
            sys.exit("BENCH schema drift:\n  " + "\n  ".join(errs))
        print("smoke ok: overhead within budget, BENCH schema stable")
        return
    _append(m)


if __name__ == "__main__":
    main()
