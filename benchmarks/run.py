"""Benchmark driver — one module per paper table/figure.
Prints ``name,value,derived`` CSV (and writes results/bench.csv)."""
import csv
import os
import sys
import time


MODULES = [
    "fig2_scaling",
    "fig3_availability",
    "fig4_failure_trace",
    "fig4_end_to_end",
    "fig6_throughput_loss",
    "fig7_spares",
    "fig8_reshard_overhead",
    "fig9_ntp_overhead",
    "fig10_blast_radius",
    "fig_serving_goodput",
    "bench_cluster",
    "bench_hotpath",
    "bench_telemetry",
    "table1_power",
    "roofline",
    "fig11_model_validation",
    "kernel_micro",
]


def main() -> None:
    only = sys.argv[1:] or None
    all_rows = []
    for name in MODULES:
        if only and name not in only:
            continue
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        try:
            rows = mod.run()
        except Exception as e:  # noqa: BLE001
            rows = [{"name": f"{name}/ERROR", "value": 0,
                     "derived": f"{type(e).__name__}: {e}"}]
        dt = time.time() - t0
        print(f"# {name} ({dt:.1f}s)", flush=True)
        for r in rows:
            print(f"{r['name']},{r['value']},{r['derived']}")
        all_rows.extend(rows)
    os.makedirs("results", exist_ok=True)
    with open("results/bench.csv", "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=["name", "value", "derived"])
        w.writeheader()
        w.writerows(all_rows)
    print(f"# wrote results/bench.csv ({len(all_rows)} rows)")


if __name__ == "__main__":
    main()
