"""BENCH_hotpath: MEASURED wall-clock trajectory of the train/serve hot paths
(ISSUE 7 tentpole — extends the BENCH_*.json series started by bench_cluster).

Train rows come from a 16-fake-device subprocess that runs the SAME plan
through both pp=2 step builders and times them (`time.perf_counter` around
`block_until_ready`, after a compile warmup):

  * the stage-sequential emulation (`core.ntp_train._make_staged_train_step`)
  * the measured submesh pipeline (`core.pp_submesh` — per-stage device
    slices, ppermute hand-off, tick-scheduled 1F1B)

On serialized fake CPU devices every stage computes every tick, so the
submesh/emulation wall ratio IS the pipeline-bubble inflation — the measured
twin of `perf_model.staged_iteration_time`'s ``pp_bubble`` term, whose
analytic factor is ``(m + pp - 1) / m``. The two must agree within
``BUBBLE_REL_TOL`` (documented in DESIGN.md §2.8: CPU dispatch overhead and
the where-gated logits put a ceiling on how tight this can be). The
cross-stage hand-off byte table the submesh step reports is recorded next to
the reshard transition ledger of a stage failure on the same session.

Overlap rows (ISSUE 9) run the SAME degraded emulated pp=2 plan with the
overlapped bucketed gradient sync (`core.overlap`, DESIGN.md §2.10) off and
on, interleaved, plus `NTPSession.measure_sync` probes of each compiled
sync. On serialized fake devices nothing truly overlaps, so the model
prediction degenerates to the launch-collapse identity
``t_on ≈ (t_off − sync_off) + sync_on`` and the measured exposed comm must
match `perf_model.exposed_comm(sync_on, window=0) = sync_on` — both gated
at ``OVERLAP_REL_TOL``. A full (non-smoke) run additionally requires
overlap-on to be strictly faster than off (the bucketed sync launches far
fewer collectives, which is exactly what CPU dispatch overhead prices).

Kernel rows time each Pallas kernel interpret-vs-compiled
(`kernels.mode.pallas_interpret` resolution); on a CPU-only host the
compiled column carries an explicit ``"skipped": "no accelerator"`` note —
the ratio is only meaningful where the backend lowers Pallas.

Usage:
  python -m benchmarks.bench_hotpath            # measure, append BENCH_*.json
  python -m benchmarks.bench_hotpath --smoke    # quick run + schema check
  (also a `run()` module for benchmarks/run.py CSV rows)

``--smoke`` additionally validates the COMMITTED BENCH_train.json /
BENCH_serve.json against the schema this code produces and exits nonzero on
key drift — that is the CI `bench-smoke` job's contract.
"""
import argparse
import json
import os
import subprocess
import sys
import time

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
TRAIN_PATH = os.path.join(REPO, "BENCH_train.json")
SERVE_PATH = os.path.join(REPO, "BENCH_serve.json")

# measured submesh/emulation wall ratio vs the analytic bubble factor
# (m+pp-1)/m: documented tolerance (DESIGN.md §2.8). Serialized-CPU dispatch
# overhead and the SPMD where-gated loss ticks both inflate the measured
# ratio, so this is loose by design; on a real multi-host accelerator the
# same contract should hold at a much tighter bound.
BUBBLE_REL_TOL = 0.40

# overlap-on step time vs the launch-collapse prediction
# t_on ≈ (t_off − sync_off) + sync_on: documented tolerance (DESIGN.md
# §2.10). Same caveats as the bubble gate — serialized-CPU dispatch noise
# on ~ms quantities keeps this loose; the identity itself is exact.
OVERLAP_REL_TOL = 0.35

# schema keys the CI bench-smoke job pins (drift = hard failure)
TRAIN_KEYS = {"config", "step_wall_ms", "bubble", "handoff", "kernels",
              "overlap"}
SERVE_KEYS = {"config", "prefill_and_decode", "kv_reshard"}


def _worker(smoke: bool) -> dict:
    """Runs inside the 16-fake-device subprocess; returns the measurements.

    All timings flow through one `repro.telemetry` recorder (spans around
    the block_until_ready'd regions, gauges for derived factors) and the
    report is read back from its MemorySink series — the bench consumes the
    same observability surface the runtime emits, instead of bespoke timer
    lists. The recorder is ACTIVE for the whole worker, so the runtime's own
    events (session spans, `kernels.dispatch` counters) land in the same
    ring and the kernel rows can cross-check their dispatch modes."""
    import numpy as np

    import jax
    import jax.numpy as jnp

    from repro import telemetry
    from repro.core import perf_model as pm
    from repro.kernels import ops
    from repro.launch.mesh import make_staged_mesh
    from repro.optim import sgd
    from repro.runtime import FailureEvent, NTPModelConfig, NTPSession
    from repro.telemetry import MemorySink, Recorder

    rec = Recorder(sinks=[MemorySink()])
    with telemetry.recording(rec):
        return _worker_recorded(smoke, rec, np, jax, jnp, pm, ops,
                                make_staged_mesh, sgd, FailureEvent,
                                NTPModelConfig, NTPSession)


def _worker_recorded(smoke, rec, np, jax, jnp, pm, ops, make_staged_mesh,
                     sgd, FailureEvent, NTPModelConfig, NTPSession) -> dict:
    LB, SEQ, MB = (4, 16, 2) if smoke else (8, 32, 4)
    # 6 smoke steps, not 2: the bubble gate estimates from per-step PAIRS,
    # and a 2-sample estimate is one scheduler hiccup away from the
    # tolerance edge; compile time dominates smoke wall time anyway
    steps = 6 if smoke else 5
    PP, D, N1 = 2, 2, 4
    cfg = NTPModelConfig(d_model=64, n_kv_groups=4, q_per_kv=2, head_dim=16,
                         d_ff=256, unit_rows=64, n_layers=4, vocab=128)

    # --- train: emulation vs submesh, same plan, same batches --------------
    mesh_emu = jax.make_mesh((D, N1), ("data", "model"))
    mesh_sub = make_staged_mesh(PP, D, N1)
    kw = dict(local_batch=LB, optimizer=sgd(0.05), key=jax.random.PRNGKey(0),
              pp=PP, microbatches=MB)
    emu = NTPSession.create(cfg, mesh_emu, **kw)
    sub = NTPSession.create(cfg, mesh_sub, **kw)
    rng = np.random.default_rng(0)

    def batch():
        return jnp.asarray(rng.integers(0, cfg.vocab, (D * LB, SEQ + 1)))

    def warmup(sess):
        # TWO warmup steps: the first compiles the fresh-params graph, the
        # second recompiles for the donated-buffer layout the steady state
        # actually runs with
        for _ in range(2):
            m = sess.step(batch())
            jax.block_until_ready((sess.params, m["loss"]))

    def one_step(sess, run):
        # the span closes only after block_until_ready, so its duration is
        # the step's true wall time, not its dispatch
        b = batch()
        with rec.span("bench.step", run=run):
            m = sess.step(b)
            jax.block_until_ready((sess.params, m["loss"]))
        return m

    def med_ms(run):
        return 1e3 * float(np.median(
            [s["dur"] for s in rec.spans("bench.step", run=run)]))

    # emulation and submesh steps INTERLEAVE so slow drifts in host load
    # land on both sides of the bubble ratio instead of biasing one loop
    warmup(emu)
    warmup(sub)
    for _ in range(steps):
        one_step(emu, "emulation")
        ms = one_step(sub, "submesh")
    t_emu, t_sub = med_ms("emulation"), med_ms("submesh")
    handoff = dict(ms["handoff"])
    # the bubble gate estimates the factor as the MEDIAN OF PER-PAIR RATIOS
    # from the interleaved steps: load transients within one pair hit both
    # numerator and denominator, and the median discards pairs where a
    # spike hit only one side — far more stable on a shared CPU host than
    # the ratio of two small-sample medians
    pair_ratios = [
        s["dur"] / e["dur"] for e, s in zip(
            rec.spans("bench.step", run="emulation"),
            rec.spans("bench.step", run="submesh"))
    ]

    # degraded stage still runs the measured path; its repack is the ledger
    sub.apply(FailureEvent(step=steps + 1, stage=1, domain=0))
    reshard_bytes = int(sub.last_transition.bytes_moved)
    warmup(sub)
    for _ in range(max(2, steps // 2)):
        one_step(sub, "submesh_degraded")
    t_deg = med_ms("submesh_degraded")

    # --- measured vs analytic bubble ---------------------------------------
    n_params = int(sum(
        np.asarray(x).size for x in jax.tree.leaves(emu.canonical_params())
    ))
    # comm-free Hardware isolates the model's schedule term: the factor
    # degenerates to exactly (m + pp - 1) / m
    hw = pm.Hardware(scaleup_bw=1e18, scaleout_bw=1e18)
    wl = pm.Workload(n_params=float(n_params), n_layers=cfg.n_layers,
                     d_model=cfg.d_model, seq_len=SEQ,
                     minibatch_tokens=float(D * LB * SEQ), act_bytes=4)
    par = pm.Parallel(tp=N1, pp=PP, dp=D, microbatch_seqs=LB // MB)
    it = pm.staged_iteration_time(hw, wl, par, (N1,) * PP)
    # measured-vs-analytic lands as a labeled gauge pair and the drift gate
    # reads the RECORDER's series, not function-local floats — the same
    # series a --telemetry run of the launcher exposes for offline diffing
    rec.gauge("bench.bubble_factor",
              it["total"] / (it["total"] - it["pp_bubble"]),
              source="analytic")
    rec.gauge("bench.bubble_factor", float(np.median(pair_ratios)),
              source="measured")
    analytic_factor = rec.values("bench.bubble_factor", source="analytic")[-1]
    measured_factor = rec.values("bench.bubble_factor", source="measured")[-1]
    rel_err = abs(measured_factor - analytic_factor) / analytic_factor

    # --- overlapped bucketed sync: off vs on, same degraded plan (§2.10) ---
    ov_kw = dict(local_batch=LB, optimizer=sgd(0.05),
                 key=jax.random.PRNGKey(0), pp=PP, microbatches=MB)
    ov_off = NTPSession.create(cfg, jax.make_mesh((D, N1), ("data", "model")),
                               overlap=False, **ov_kw)
    ov_on = NTPSession.create(cfg, jax.make_mesh((D, N1), ("data", "model")),
                              overlap=True, **ov_kw)
    for s in (ov_off, ov_on):
        warmup(s)
        # a degraded stage makes the sync heaviest (reshard→psum→reshard per
        # bucket/leaf) — the paper-relevant path and the largest collapse
        s.apply(FailureEvent(step=3, stage=1, domain=0))
        warmup(s)  # recompile for the degraded plan + donated layout
    for _ in range(steps):
        one_step(ov_off, "overlap_off")
        one_step(ov_on, "overlap_on")
    t_off, t_on = med_ms("overlap_off"), med_ms("overlap_on")
    # two probes each: the first compiles grads_fn/sync_fn, the second is
    # the steady-state sync wall time (train.sync spans land in the ring)
    for s in (ov_off, ov_on):
        s.measure_sync(batch())
    p_off, p_on = ov_off.measure_sync(batch()), ov_on.measure_sync(batch())
    sync_off_ms, sync_on_ms = p_off["sync_s"] * 1e3, p_on["sync_s"] * 1e3
    # serialized fake devices leave no backward window to hide the sync in,
    # so the model's exposed comm degenerates to the full bucketed sync and
    # the step prediction to the launch-collapse identity
    predicted_exposed_ms = pm.exposed_comm(sync_on_ms, 0.0)
    predicted_on_ms = (t_off - sync_off_ms) + predicted_exposed_ms
    measured_exposed_ms = max(0.0, t_on - (t_off - sync_off_ms))
    ov_rel_err = abs(predicted_on_ms - t_on) / t_on
    rec.gauge("bench.overlap_step_ms", t_off, mode="off")
    rec.gauge("bench.overlap_step_ms", t_on, mode="on")
    overlap_row = {
        "step_wall_ms": {"off": round(t_off, 1), "on": round(t_on, 1)},
        "sync_ms": {"off": round(sync_off_ms, 1), "on": round(sync_on_ms, 1)},
        "collectives": {"off": int(p_off["collectives"]),
                        "on": int(p_on["collectives"])},
        "exposed_ms": {"measured": round(measured_exposed_ms, 1),
                       "predicted": round(predicted_exposed_ms, 1)},
        "predicted_on_ms": round(predicted_on_ms, 1),
        "rel_err": round(ov_rel_err, 4),
        "tolerance": OVERLAP_REL_TOL,
        "within_tolerance": bool(ov_rel_err <= OVERLAP_REL_TOL),
        "on_faster": bool(t_on < t_off),
    }

    # --- per-kernel interpret vs compiled ----------------------------------
    krng = np.random.default_rng(1)
    q = jnp.asarray(krng.normal(size=(1, 2, 128, 32)), jnp.float32)
    k = jnp.asarray(krng.normal(size=(1, 1, 128, 32)), jnp.float32)
    xr = jnp.asarray(krng.normal(size=(256, 64)), jnp.float32)
    wr = jnp.ones((64,), jnp.float32)
    xs = jnp.asarray(krng.normal(size=(2, 64, 8)), jnp.float32)
    dts = jnp.asarray(krng.uniform(0.01, 0.2, size=(2, 64)), jnp.float32)
    As = jnp.asarray(-krng.uniform(0.5, 2.0, size=(2,)), jnp.float32)
    Bs = jnp.asarray(krng.normal(size=(2, 64, 16)) * 0.3, jnp.float32)
    src = jnp.asarray(krng.normal(size=(9, 64)), jnp.float32)
    idx = jnp.asarray(krng.integers(0, 9, size=(4, 3)), jnp.int32)
    calls = {
        "flash_attention": lambda i: ops.flash_attention(
            q, k, k, block_q=64, block_k=64, interpret=i),
        "rmsnorm": lambda i: ops.rmsnorm(xr, wr, block_rows=64, interpret=i),
        "ssd_scan": lambda i: ops.ssd_scan(xs, dts, As, Bs, Bs, chunk=32,
                                           interpret=i),
        "reshard_pack": lambda i: ops.reshard_pack(src, idx, interpret=i),
    }

    def time_us(f, n=3 if smoke else 10, label="misc"):
        jax.block_until_ready(f())
        with rec.span("bench.kernel_loop", label=label):
            for _ in range(n):
                jax.block_until_ready(f())
        dur = rec.spans("bench.kernel_loop", label=label)[-1]["dur"]
        return round(dur / n * 1e6, 1)

    kernels = {}
    for name, call in calls.items():
        row = {"interpret_us": time_us(lambda: call(True),
                                       label=f"{name}:interpret"),
               "compiled_us": None, "ratio": None, "note": ""}
        try:
            row["compiled_us"] = time_us(lambda: call(False),
                                         label=f"{name}:compiled")
            row["ratio"] = round(row["interpret_us"] / row["compiled_us"], 2)
        except Exception as e:  # noqa: BLE001 — CPU cannot lower Pallas
            # explicit skip marker: a null compiled column without it is
            # schema drift (the guard rejects bare nulls)
            row["skipped"] = "no accelerator"
            row["note"] = (f"backend {jax.default_backend()!r} cannot "
                           f"compile Pallas ({type(e).__name__})")
        # the dispatch counter the active recorder collected from
        # kernels.mode — proof of which mode each public wrapper resolved
        row["dispatches"] = {
            mode: int(rec.total("kernels.dispatch", kernel=name, mode=mode))
            for mode in ("interpret", "compiled")
        }
        kernels[name] = row

    train = {
        "config": {"model": "d64-L4-kv4", "pp": PP, "data": D, "n1": N1,
                   "local_batch": LB, "seq_len": SEQ, "microbatches": MB,
                   "steps_timed": steps, "smoke": smoke,
                   "backend": jax.default_backend()},
        "step_wall_ms": {"emulation": round(t_emu, 1),
                         "submesh": round(t_sub, 1),
                         "submesh_degraded": round(t_deg, 1)},
        "bubble": {
            "measured_factor": round(measured_factor, 4),
            "analytic_factor": round(analytic_factor, 4),
            "analytic_fraction": round(it["pp_bubble"] / it["total"], 4),
            "measured_fraction": round(1.0 - t_emu / t_sub, 4),
            "rel_err": round(rel_err, 4),
            "tolerance": BUBBLE_REL_TOL,
            "within_tolerance": bool(rel_err <= BUBBLE_REL_TOL),
        },
        "handoff": dict(handoff, reshard_transition_bytes=reshard_bytes),
        "kernels": kernels,
        "overlap": overlap_row,
    }

    # --- serve: continuous-batching decode loop ----------------------------
    from repro.configs.base import ArchConfig
    from repro.serve import Request, Router, ServeSession

    scfg = ArchConfig(
        arch_id="hotpath-serve-kv4", family="dense", citation="bench",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=128, layer_pattern=("attn",),
    )
    n_req, max_new = (3, 4) if smoke else (8, 12)
    sess = ServeSession.create(scfg, replicas=1, n1=N1, slots=4, max_len=64,
                               prefill_len=16, key=jax.random.PRNGKey(0))
    router = Router(sess)
    srng = np.random.default_rng(0)
    for i in range(n_req):
        router.submit(Request(
            rid=i, max_new=max_new,
            prompt=srng.integers(1, 128, size=8).astype(np.int32)))
    guard = 0
    while router.queue or any(e.n_active for e in sess.engines):
        with rec.span("bench.serve_tick"):
            router.step()
        guard += 1
        assert guard < 2000, "serve bench did not converge"
    tick_ms = [s["dur"] * 1e3 for s in rec.spans("bench.serve_tick")]
    # first tick admits + prefills + compiles; steady-state is the tail
    steady = tick_ms[len(tick_ms) // 2:]
    decode_ms = float(np.median(steady))
    toks = n_req * max_new

    # KV reshard hot path: kernel route vs jnp route (interpret on CPU)
    from repro.reshard import engine as rse
    from repro.reshard import planner

    tables = planner.tables(planner.sync_key(8, N1, N1),
                            planner.sync_key(8, N1, 2), 8)
    kv = jnp.asarray(srng.normal(size=(N1, 8, 4, 16)), jnp.float32)
    jnp_us = time_us(lambda: rse.reshard_ranks(kv, tables, use_kernel=False),
                     label="kv_reshard:jnp")
    ker_us = time_us(lambda: rse.reshard_ranks(kv, tables, use_kernel=True),
                     label="kv_reshard:kernel")

    serve = {
        "config": {"arch": scfg.arch_id, "n1": N1, "slots": 4,
                   "requests": n_req, "max_new": max_new, "smoke": smoke,
                   "backend": jax.default_backend()},
        "prefill_and_decode": {
            "first_tick_ms": round(tick_ms[0], 1),       # admit+prefill+jit
            "decode_tick_ms": round(decode_ms, 2),
            "ticks": len(tick_ms),
            "tokens_decoded": toks,
            "tokens_per_s": round(toks / (sum(tick_ms) / 1e3), 1),
        },
        "kv_reshard": {
            "jnp_us": jnp_us, "kernel_us": ker_us,
            "kernel_over_jnp": round(ker_us / jnp_us, 2),
            "mode": ("interpret" if jax.default_backend() == "cpu"
                     else "compiled"),
        },
    }
    return {"train": train, "serve": serve}


def measure(smoke: bool = False) -> dict:
    """Spawn the measurement subprocess (needs its own XLA device count —
    jax may already be initialized in this process) and parse its report."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=16",
               JAX_PLATFORMS=os.environ.get("JAX_PLATFORMS", ""),
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(REPO, "src"), REPO,
                    os.environ.get("PYTHONPATH", "")]))
    cmd = [sys.executable, "-m", "benchmarks.bench_hotpath", "--worker"]
    if smoke:
        cmd.append("--smoke")
    out = subprocess.run(cmd, capture_output=True, text=True, env=env,
                         cwd=REPO, timeout=2700)
    for line in reversed(out.stdout.splitlines()):
        if line.startswith("HOTPATH_JSON "):
            return json.loads(line[len("HOTPATH_JSON "):])
    raise RuntimeError(
        f"hotpath worker produced no report (rc={out.returncode}):\n"
        f"{out.stdout[-2000:]}\n{out.stderr[-2000:]}")


def _check_schema(path: str, want_keys: set, bench: str) -> list:
    """CI drift guard: the committed BENCH file's latest run must carry
    exactly the top-level keys this code produces."""
    errs = []
    if not os.path.exists(path):
        return [f"{os.path.basename(path)} missing"]
    with open(path) as f:
        doc = json.load(f)
    if doc.get("bench") != bench or not doc.get("runs"):
        errs.append(f"{os.path.basename(path)}: bad header/empty runs")
        return errs
    last = doc["runs"][-1]
    got = set(last) - {"date"}
    if got != want_keys:
        errs.append(f"{os.path.basename(path)}: run keys {sorted(got)} != "
                    f"expected {sorted(want_keys)}")
    if bench == "hotpath_train" and not errs:
        # kernel rows: a null compiled column must carry the explicit skip
        # marker, never a bare null
        for name, row in last.get("kernels", {}).items():
            if (row.get("compiled_us") is None
                    and row.get("skipped") != "no accelerator"):
                errs.append(f"kernel row {name!r}: null compiled_us without "
                            "an explicit 'skipped: no accelerator' note")
        want_ov = {"step_wall_ms", "sync_ms", "collectives", "exposed_ms",
                   "predicted_on_ms", "rel_err", "tolerance",
                   "within_tolerance", "on_faster"}
        missing = want_ov - set(last.get("overlap", {}))
        if missing:
            errs.append(f"overlap row missing keys {sorted(missing)}")
    return errs


def run():
    """benchmarks/run.py entry point — CSV rows from one full measurement."""
    m = measure(smoke=False)
    t, s = m["train"], m["serve"]
    w, b = t["step_wall_ms"], t["bubble"]
    return [
        {"name": "hotpath/train_step_ms/submesh", "value": w["submesh"],
         "derived": f"emulation={w['emulation']} "
                    f"degraded={w['submesh_degraded']}"},
        {"name": "hotpath/bubble_factor/measured",
         "value": b["measured_factor"],
         "derived": f"analytic={b['analytic_factor']} rel_err={b['rel_err']} "
                    f"tol={b['tolerance']} ok={b['within_tolerance']}"},
        {"name": "hotpath/handoff_bytes/step",
         "value": t["handoff"]["total_bytes"],
         "derived": f"reshard_transition="
                    f"{t['handoff']['reshard_transition_bytes']}"},
        {"name": "hotpath/overlap_step_ms/on",
         "value": t["overlap"]["step_wall_ms"]["on"],
         "derived": f"off={t['overlap']['step_wall_ms']['off']} "
                    f"collectives={t['overlap']['collectives']} "
                    f"rel_err={t['overlap']['rel_err']}"},
        {"name": "hotpath/serve_decode_tick_ms",
         "value": s["prefill_and_decode"]["decode_tick_ms"],
         "derived": f"tokens_per_s="
                    f"{s['prefill_and_decode']['tokens_per_s']}"},
    ]


def _append(path: str, bench: str, rec: dict) -> None:
    doc = {"bench": bench, "schema": 1, "runs": []}
    if os.path.exists(path):
        with open(path) as f:
            doc = json.load(f)
    rec["date"] = time.strftime("%Y-%m-%d")
    doc["runs"].append(rec)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"appended run {len(doc['runs'])} to {path}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small geometry + committed-BENCH schema check "
                         "(the CI bench-smoke contract); does not write")
    ap.add_argument("--worker", action="store_true", help=argparse.SUPPRESS)
    args = ap.parse_args()

    if args.worker:
        doc = _worker(args.smoke)
        print("HOTPATH_JSON " + json.dumps(doc))
        return

    m = measure(smoke=args.smoke)
    print(json.dumps(m, indent=2))
    if not m["train"]["bubble"]["within_tolerance"]:
        sys.exit("measured bubble factor outside the documented tolerance "
                 f"({m['train']['bubble']})")
    ov = m["train"]["overlap"]
    if not ov["within_tolerance"]:
        sys.exit("overlap-on step time disagrees with the launch-collapse "
                 f"prediction beyond the documented tolerance ({ov})")
    if not args.smoke and not ov["on_faster"]:
        sys.exit("overlap-on was not faster than overlap-off in a full run "
                 f"({ov})")
    if args.smoke:
        errs = (_check_schema(TRAIN_PATH, TRAIN_KEYS, "hotpath_train")
                + _check_schema(SERVE_PATH, SERVE_KEYS, "hotpath_serve"))
        if errs:
            sys.exit("BENCH schema drift:\n  " + "\n  ".join(errs))
        print("smoke ok: measurements in tolerance, BENCH schemas stable")
        return
    _append(TRAIN_PATH, "hotpath_train", m["train"])
    _append(SERVE_PATH, "hotpath_serve", m["serve"])


if __name__ == "__main__":
    main()
